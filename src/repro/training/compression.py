"""Gradient compression for cross-pod data parallelism.

``quantized_psum``: int8 ring all-reduce with per-chunk scales and local
fp32 accumulation — the wire format is int8 + one fp32 scale per shard, a
~3.9x reduction over fp32 all-reduce on the slow pod-interconnect, at the
cost of (n-1) quantization roundings.  Error feedback (residual carried
across steps) makes it unbiased in the long run.

Used inside ``shard_map`` over the ``pod`` axis; within a pod, gradients
reduce in native bf16 through XLA's fused reduce-scatter.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def _quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def quantized_psum(x: jnp.ndarray, axis_name: str, n_shards: int
                   ) -> jnp.ndarray:
    """Ring all-reduce with int8 wire format. x: fp32, any shape."""
    if n_shards == 1:
        return x
    perm = [(i, (i + 1) % n_shards) for i in range(n_shards)]
    acc = x.astype(jnp.float32)
    q, s = _quantize(x.astype(jnp.float32))
    for _ in range(n_shards - 1):
        q = jax.lax.ppermute(q, axis_name, perm)
        s = jax.lax.ppermute(s, axis_name, perm)
        acc = acc + q.astype(jnp.float32) * s
    return acc


def compressed_grad_sync(grads, axis_name: str, n_shards: int,
                         residual=None):
    """Apply quantized_psum to every leaf, with error feedback."""
    if residual is None:
        residual = jax.tree.map(jnp.zeros_like, grads)

    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        summed = quantized_psum(g32, axis_name, n_shards) / n_shards
        # residual: what the wire format lost locally
        q, s = _quantize(g32)
        new_r = g32 - q.astype(jnp.float32) * s
        return summed.astype(g.dtype), new_r

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (tdef.unflatten([o[0] for o in outs]),
            tdef.unflatten([o[1] for o in outs]))

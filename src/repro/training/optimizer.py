"""AdamW on raw pytrees (no optax offline), sharding-transparent.

Moments are fp32 and inherit each parameter's sharding (elementwise update
=> zero extra collectives under pjit).  The update runs in fp32 and casts
back to the parameter dtype (bf16 master-less recipe; flip
``master_weights=True`` to carry fp32 masters).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    master_weights: bool = False


def adamw_init(params, cfg: AdamWConfig) -> Dict[str, Any]:
    def zeros(p):
        return jnp.zeros(p.shape, jnp.float32)
    state = {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }
    if cfg.master_weights:
        state["master"] = jax.tree.map(
            lambda p: p.astype(jnp.float32), params)
    return state


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def global_norm(tree) -> jnp.ndarray:
    sq = jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))),
                      tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq))


def adamw_update(params, grads, state, cfg: AdamWConfig
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip else 1.0
    lr = _schedule(cfg, step)
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    src = state.get("master", params)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        pf = p.astype(jnp.float32)
        pf = pf - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                        + cfg.weight_decay * pf)
        return pf, m, v

    flat_p, tdef = jax.tree.flatten(src)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    outs = [upd(p, g, m, v) for p, g, m, v in
            zip(flat_p, flat_g, flat_m, flat_v)]
    new_f32 = tdef.unflatten([o[0] for o in outs])
    new_state = {
        "step": step,
        "m": tdef.unflatten([o[1] for o in outs]),
        "v": tdef.unflatten([o[2] for o in outs]),
    }
    if cfg.master_weights:
        new_state["master"] = new_f32
    new_params = jax.tree.map(lambda nf, p: nf.astype(p.dtype),
                              new_f32, params)
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
